"""Fused int8 segment boundaries: the sampler step that *is* the handoff.

A compressed relay handoff used to be three separate dispatches bracketing
the samplers — quantize → wire → dequantize — so the fp16 latent was fully
materialized in HBM on both sides of every segment boundary.  This module
fuses the boundary into the steps themselves:

* **emit** — the *last* edge-segment step combines CFG, applies the
  two-term step update and writes the wire payload ``{"q" int8, "s" fp32}``
  over the handoff's channel-row layout
  (:func:`repro.quantization.quant_latent`) in one fused dispatch;
* **consume** — the *first* device-segment step reads the wire payload as
  its latent operand (the int8 rows dequantize in-register) and steps
  straight off it.

Two backends share one contract.  The default is a jnp composition under a
single ``jax.jit`` — XLA fuses the elementwise tail with the quantize (one
latent read, one wire write), which is also the only backend that runs on
CPU.  On TPU the hand-fused Pallas kernels
(:mod:`repro.kernels.fused_sampler`) instantiate the same math; they are
bit-parity-locked against the jnp path in interpret mode
(``tests/test_fused_boundary.py``).

**Parity contract** (what ``tests/test_fused_boundary.py`` locks): against
the unfused `step → latent_roundtrip → step` sequence, `emit → consume`
produces the *exact* int8 payload and byte accounting, scales within 1
float32 ulp, and numerically equivalent latents/deviations (~1e-6
relative).  The tails reuse the same step math
(:func:`repro.core.samplers.step_update`, two-term form) and the same wire
halves (:func:`repro.quantization.quant_latent` / :func:`dequant_latent`)
the unfused path composes, but XLA repartitions the fused program — FMA
contraction and reciprocal-multiply selection differ per compilation
unit, so cross-unit bitwise identity is not a property CPU XLA offers.
The Pallas kernels, however, ARE bit-parity-locked against their jitted
jnp oracles in interpret mode — payload ints, scales and stepped rows all
exact.

The jitted tails live in a module-level cache keyed by static config
(kind, quantizer, guidance, flavor); :func:`warm` pre-fires them so the
first relay request doesn't eat their compile time, and
:func:`cache_stats` exposes per-config compiled-trace counts for the
telemetry asserts.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import samplers
from repro.quantization import (dequant_latent, latent_to_rows,
                                payload_bytes, quant_latent,
                                relative_deviation)

# emit flavors: what the fused producer step returns beyond the payload.
#   "wire"            — payload only (the serving fast path; kernel-backed
#                       on TPU: the fp16 latent never touches HBM)
#   "wire_dev"        — + the Eq. 1 deviation pct of the payload vs the
#                       stepped latent (relay/DAG accounting)
#   "wire_dev_latent" — + the stepped latent itself (graph nodes whose
#                       other consumers need it: joins, mixed edges, sink)
EMIT_FLAVORS = ("wire", "wire_dev", "wire_dev_latent")

_jits: Dict[Tuple, Callable] = {}  # static boundary config -> jitted tail


def _combine(ec, eu, guidance: float):
    """cfg_combine on pre-evaluated nets — same skip semantics (guidance
    1.0 returns ε_c untouched), so fused and unfused guidance follow the
    same code path."""
    if guidance == 1.0:
        return ec
    return eu + guidance * (ec - eu)


def _net_eps(fn, params, x, t, cond, uncond, guidance: float):
    """Evaluate the denoiser(s) for one step: (ε_c, ε_u, effective
    guidance).  Mirrors ``cfg_combine``'s call pattern: no uncond or unit
    scale → a single evaluation."""
    if uncond is None or guidance == 1.0:
        ec = fn(params, x, t, cond)
        return ec, ec, 1.0
    return fn(params, x, t, cond), fn(params, x, t, uncond), float(guidance)


def emit_fn(kind: str, quantizer: str = "rowwise", guidance: float = 1.0,
            flavor: str = "wire", use_kernel: bool = False,
            interpret: bool = False) -> Callable:
    """The cached jitted emit tail for one boundary config.

    Signature: ``tail(x, ec, eu, coeffs) -> dict`` with key ``"wire"`` (the
    payload) and, per ``flavor``, ``"dev_pct"`` / ``"latent"``.  ``coeffs``
    is the (2,) vector from :func:`samplers.step_coeffs`.  With
    ``use_kernel`` the Pallas emit kernel replaces the jnp tail
    (``flavor="wire"`` only — the accounting flavors keep the stepped
    latent live by definition, so there is nothing to elide)."""
    if flavor not in EMIT_FLAVORS:
        raise ValueError(f"unknown emit flavor {flavor!r}; one of {EMIT_FLAVORS}")
    if use_kernel and (flavor != "wire" or quantizer != "rowwise"):
        raise ValueError(
            "kernel-backed emit supports flavor='wire' with the rowwise "
            f"quantizer only (got flavor={flavor!r}, quantizer={quantizer!r})"
        )
    key = ("emit", kind, quantizer, float(guidance), flavor, use_kernel,
           interpret)
    if key in _jits:
        return _jits[key]

    if use_kernel:
        from repro.kernels.fused_sampler.ops import fused_cfg_step_quant

        def tail(x, ec, eu, coeffs):
            q, s = fused_cfg_step_quant(
                latent_to_rows(x), latent_to_rows(ec), latent_to_rows(eu),
                coeffs, guidance=float(guidance), mode=kind,
                interpret=interpret,
            )
            return {"wire": {"q": q, "s": s}}
    else:
        def tail(x, ec, eu, coeffs):
            out = samplers.step_update(kind, x, _combine(ec, eu, guidance),
                                       coeffs)
            qs, _ = quant_latent(out, quantizer)
            res = {"wire": qs}
            if flavor != "wire":
                rec = dequant_latent(qs, out.shape[-3:], out.dtype, quantizer)
                res["dev_pct"] = relative_deviation(out, rec) * 100.0
            if flavor == "wire_dev_latent":
                res["latent"] = out
            return res

    _jits[key] = jax.jit(tail)
    return _jits[key]


def peek_fn(quantizer: str = "rowwise") -> Callable:
    """The cached jitted wire→latent reconstruction,
    ``peek(q, s, latent_shape)`` — what the consuming step's denoiser reads
    (the same bits the unfused wire would deliver).  ``latent_shape`` is a
    static (H, W, C) tuple."""
    key = ("peek", quantizer)
    if key not in _jits:
        def f(q, s, latent_shape):
            return dequant_latent({"q": q, "s": s}, latent_shape,
                                  jnp.float32, quantizer)

        _jits[key] = jax.jit(f, static_argnames=("latent_shape",))
    return _jits[key]


def consume_fn(kind: str, quantizer: str = "rowwise", guidance: float = 1.0,
               use_kernel: bool = False, interpret: bool = False) -> Callable:
    """The cached jitted consume tail:
    ``tail(q, s, ec, eu, coeffs, latent_shape) -> next latent``.  The step
    update reads the wire payload directly (int8 rows instead of the fp32
    reconstruction); with ``use_kernel`` the Pallas consume kernel
    instantiates it (rowwise quantizer only)."""
    if use_kernel and quantizer != "rowwise":
        raise ValueError(
            "kernel-backed consume supports the rowwise quantizer only "
            f"(got {quantizer!r})"
        )
    key = ("consume", kind, quantizer, float(guidance), use_kernel, interpret)
    if key in _jits:
        return _jits[key]

    if use_kernel:
        from repro.kernels.fused_sampler.ops import fused_cfg_step_dequant
        from repro.quantization import rows_to_latent

        def tail(q, s, ec, eu, coeffs, latent_shape):
            rows = fused_cfg_step_dequant(
                q, s, latent_to_rows(ec), latent_to_rows(eu), coeffs,
                guidance=float(guidance), mode=kind, interpret=interpret,
            )
            return rows_to_latent(rows, latent_shape, jnp.float32)
    else:
        def tail(q, s, ec, eu, coeffs, latent_shape):
            x = dequant_latent({"q": q, "s": s}, latent_shape, jnp.float32,
                               quantizer)
            return samplers.step_update(kind, x, _combine(ec, eu, guidance),
                                        coeffs)

    _jits[key] = jax.jit(tail, static_argnames=("latent_shape",))
    return _jits[key]


# ---------------------------------------------------------------------------
# step-level drivers — what execute_program / the executor's segment fns call
# ---------------------------------------------------------------------------


def quant_step(kind: str, fn, params, x, sigmas, i, cond, uncond,
               guidance: float, *, quantizer: str = "rowwise",
               flavor: str = "wire", use_kernel: bool = False,
               interpret: bool = False) -> dict:
    """Run sampler step ``i`` and emit the wire payload in the same fused
    dispatch — the producer side of a compressed segment boundary.

    Returns a dict with ``"wire"`` (the ``{"q", "s"}`` payload),
    ``"bytes"`` (static payload bytes, same accounting as
    ``latent_roundtrip``), and per ``flavor`` ``"dev_pct"`` /
    ``"latent"``.  ``i`` may be a traced int32 (the executor's traced
    segment bounds)."""
    ec, eu, g = _net_eps(fn, params, x, sigmas[i], cond, uncond, guidance)
    coeffs = samplers.step_coeffs(kind, sigmas, i)
    res = dict(emit_fn(kind, quantizer, g, flavor, use_kernel, interpret)(
        x, ec, eu, coeffs
    ))
    res["bytes"] = payload_bytes(res["wire"])
    return res


def dequant_step(kind: str, fn, params, qs: dict, latent_shape, sigmas, i,
                 cond, uncond, guidance: float, *,
                 quantizer: str = "rowwise", use_kernel: bool = False,
                 interpret: bool = False):
    """Run sampler step ``i`` straight off the wire payload — the consumer
    side of a compressed segment boundary.  The denoiser sees the
    reconstructed latent (the same payload the unfused wire delivers);
    the step tail reads the int8 payload.  Returns the next latent."""
    latent_shape = tuple(latent_shape)
    x = peek_fn(quantizer)(qs["q"], qs["s"], latent_shape)
    ec, eu, g = _net_eps(fn, params, x, sigmas[i], cond, uncond, guidance)
    coeffs = samplers.step_coeffs(kind, sigmas, i)
    return consume_fn(kind, quantizer, g, use_kernel, interpret)(
        qs["q"], qs["s"], ec, eu, coeffs, latent_shape
    )


# ---------------------------------------------------------------------------
# warm-up + telemetry
# ---------------------------------------------------------------------------


def warm(latent_shape, batch: int = 4, kinds=("ddim", "rf"),
         quantizer: str = "rowwise", guidance: float = 1.0) -> int:
    """Pre-compile the fused boundary tails for one latent shape: both
    sampler kinds, both emit accounting flavors, the wire peek and the
    consume tail.  Called from ``HandoffTransport.warm`` / the executor's
    JIT pre-fire so the first compressed relay request doesn't pay the
    boundary compiles.  Returns the number of tail calls fired (every one
    lands in :func:`cache_stats`)."""
    latent_shape = tuple(latent_shape)
    x = jnp.zeros((batch,) + latent_shape, jnp.float32)
    eps = jnp.zeros_like(x)
    n = 0
    for kind in kinds:
        # any valid coefficient pair compiles the trace; values don't matter
        coeffs = jnp.asarray([0.5, 0.6], jnp.float32)
        wire = None
        for flavor in ("wire", "wire_dev"):
            res = emit_fn(kind, quantizer, guidance, flavor)(x, eps, eps,
                                                             coeffs)
            wire = res["wire"]
            n += 1
        peek_fn(quantizer)(wire["q"], wire["s"], latent_shape)
        n += 1
        consume_fn(kind, quantizer, guidance)(
            wire["q"], wire["s"], eps, eps, coeffs, latent_shape
        )
        n += 1
    return n


def cache_stats() -> Dict[str, int]:
    """Compile-cache telemetry: per-config compiled-trace counts of every
    cached boundary tail (``jax.jit``'s own trace cache — one entry per
    shape signature seen).  The warm-path tests assert these are nonzero
    after :func:`warm` and *unchanged* after the first real request."""
    out = {}
    for key, fn in _jits.items():
        label = "/".join(str(k) for k in key)
        try:
            out[label] = int(fn._cache_size())
        except AttributeError:  # pragma: no cover - older jax
            out[label] = -1
    return out


def clear_cache() -> None:
    """Drop every cached boundary tail (test isolation)."""
    _jits.clear()
