"""Dynamic composite reward (paper Eqs. 12–13).

r = Σ_m w_m·Q_m − w_time·t_total − w_cost·m_vram − γ·l_dev, tanh-compressed.
Weights adapt to the request context (text-rendering / speed / quality /
low-battery regimes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict

import numpy as np

ETA = 20.0  # tanh compression scale (r_final ∈ (−η, η))

BASE_WEIGHTS = {
    "clip": 8.0,
    "ir": 4.0,
    "pick": 20.0,
    "aes": 0.6,
    "ocr": 6.0,
}
BASE_W_TIME = 0.35
BASE_W_COST = 0.08
BASE_GAMMA = 1.5


@dataclass
class RewardInputs:
    quality: Dict[str, float]  # keys: clip, ir, pick, aes, ocr
    t_total: float  # end-to-end latency incl. queueing (s)
    m_vram: float  # peak VRAM of the chosen configuration (GB)
    l_dev: float  # max occupancy of the pools used ∈ [0,1]
    # context flags
    c_txt: float = 0.0
    c_pref: float = 0.0
    c_bat: float = 0.0


@lru_cache(maxsize=8)
def _weights_for(txt: bool, pref: bool, bat: bool):
    """Weight sets depend only on the three thresholded context flags, so
    there are exactly 8 of them — built once each, then reused (the reward
    path runs once per completed request).  Returned structures are shared:
    treat them as read-only."""
    w = dict(BASE_WEIGHTS)
    w_time, w_cost, gamma = BASE_W_TIME, BASE_W_COST, BASE_GAMMA
    if txt:  # text-rendering: raise OCR, drop visual weights
        w["ocr"] *= 4.0
        for k in ("clip", "ir", "pick", "aes"):
            w[k] *= 0.5
    if pref:  # speed-sensitive: amplify time, halve quality
        w_time *= 2.5
        for k in w:
            w[k] *= 0.5
    else:  # quality-focused: maximize CLIP/IR, reduce time
        w["clip"] *= 1.5
        w["ir"] *= 1.5
        w_time *= 0.6
    if bat:  # low battery: scale up cost and time penalties
        w_cost *= 2.0
        w_time *= 1.5
    return w, w_time, w_cost, gamma


def dynamic_weights(c_txt: float, c_pref: float, c_bat: float):
    w, w_time, w_cost, gamma = _weights_for(
        c_txt >= 0.5, c_pref > 0.5, c_bat >= 0.5
    )
    return dict(w), w_time, w_cost, gamma  # copy: callers may mutate


def compute_reward(x: RewardInputs, *, dynamic: bool = True) -> float:
    """Eqs. 12–13 → compressed reward in (−η, η).  ``dynamic=False`` freezes
    the weights at their base values (Table IV "w/o Dynamic Reward")."""
    if dynamic:
        w, w_time, w_cost, gamma = _weights_for(
            x.c_txt >= 0.5, x.c_pref > 0.5, x.c_bat >= 0.5
        )
    else:
        w, w_time, w_cost, gamma = BASE_WEIGHTS, BASE_W_TIME, BASE_W_COST, BASE_GAMMA
    q = x.quality
    r = sum(w[k] * q.get(k, 0.0) for k in w)
    r -= w_time * x.t_total + w_cost * x.m_vram + gamma * x.l_dev
    return float(ETA * np.tanh(r / ETA))
