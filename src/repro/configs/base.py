"""Architecture / shape configuration system.

Every assigned architecture is expressed as an ``ArchConfig`` built from a
repeating ``LayerSpec`` *super-block* (so the transformer can scan over
homogeneously-stacked parameters) plus an optional unrolled remainder.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer / sub-config specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a super-block."""

    mixer: str = "attn"  # attn | rglru | mlstm | slstm
    window: Optional[int] = None  # sliding-window size for local attention
    mlp: str = "dense"  # dense | moe | none
    cross_attn: bool = False  # inject cross-attention to ctx embeddings


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    n_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    absorb: bool = False  # decode-time weight absorption (perf variant)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec archs (whisper).  Frontend is a stub: the
    ``input_specs`` supply precomputed frame embeddings."""

    n_layers: int = 24
    n_frames: int = 1500
    d_model: int = 1024
    n_heads: int = 16
    d_ff: int = 4096


@dataclass(frozen=True)
class ArchConfig:
    name: str = "arch"
    family: str = "dense"  # dense | hybrid | ssm | moe | audio | vlm
    source: str = ""  # provenance citation

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 512

    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    remainder: Tuple[LayerSpec, ...] = ()

    # attention details
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    # zero-pad query heads (group-preserving) up to the TP degree so
    # attention shards on heads instead of head_dim — kills the O(S²)
    # score all-reduces when n_heads doesn't divide the model axis
    # (llama4's 40 heads on TP-16; see EXPERIMENTS.md §Perf)
    attn_head_padding: bool = False

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None

    # recurrent (rglru / xlstm) dims
    rnn_width: int = 0
    conv_width: int = 4

    encoder: Optional[EncoderConfig] = None

    # cross-attn context (vision patches / audio frames), provided pre-embedded
    ctx_len: int = 0
    ctx_dim: int = 0

    tie_embeddings: bool = True
    mtp: bool = False  # DeepSeek-V3 multi-token-prediction head
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    subquadratic: bool = False  # eligible for long_500k decode
    has_decoder: bool = True  # encoder-only archs would skip decode shapes

    # -- derived ----------------------------------------------------------
    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.remainder)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers not divisible into "
            f"pattern of {len(self.pattern)} (+{len(self.remainder)} remainder)"
        )
        return body // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so TP-16 sharding always divides evenly."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ArchConfig) -> Tuple[ShapeSpec, ...]:
    """Shape cells that run for this arch (skip rules per DESIGN.md)."""
    out = []
    for s in ALL_SHAPES:
        if s.kind == "decode" and not cfg.has_decoder:
            continue  # encoder-only: no decode step
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # needs sub-quadratic attention / recurrent state
        out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def make_reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink a full config to a CPU-runnable config of the same family:
    same pattern structure, tiny dims."""
    moe = None
    if cfg.moe is not None:
        # capacity_factor=4: no token drops at smoke-test scale, so cached
        # decode matches the teacher-forced forward exactly
        moe = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            n_shared=min(cfg.moe.n_shared, 1), capacity_factor=4.0,
        )
    mla = None
    if cfg.mla is not None:
        mla = dataclasses.replace(
            cfg.mla, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
            qk_rope_dim=8, v_head_dim=16,
        )
    enc = None
    if cfg.encoder is not None:
        enc = dataclasses.replace(
            cfg.encoder, n_layers=2, n_frames=16, d_model=64, n_heads=2, d_ff=128
        )

    # shrink layer count: keep one super-block repeat + remainder
    n_layers = len(cfg.pattern) + len(cfg.remainder)
    # shrink windows so local attention is exercised at tiny seq lens
    pattern = tuple(
        dataclasses.replace(l, window=(4 if l.window else None)) for l in cfg.pattern
    )
    remainder = tuple(
        dataclasses.replace(l, window=(4 if l.window else None)) for l in cfg.remainder
    )
    return cfg.replace(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=pattern,
        remainder=remainder,
        moe=moe,
        mla=mla,
        encoder=enc,
        rnn_width=64 if cfg.rnn_width else 0,
        ctx_len=8 if cfg.ctx_len else 0,
        ctx_dim=32 if cfg.ctx_dim else 0,
        dtype="float32",
    )
