"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer.  The vision
tower is a STUB: ``input_specs`` provides precomputed patch embeddings
(B, 1600, 7680) which the model projects to d_model.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig, LayerSpec

SELF = LayerSpec(mixer="attn", mlp="dense")
XATT = LayerSpec(mixer="attn", mlp="dense", cross_attn=True)

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=(XATT, SELF, SELF, SELF, SELF),  # ×8 — cross-attn every 5th
    ctx_len=1600,
    ctx_dim=7680,
    tie_embeddings=False,
    rope_theta=500000.0,
)
