"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048; MoE 128 experts top-1 + shared, interleaved
dense/MoE layers, early-fusion multimodal (stub: model accepts
``inputs_embeds`` with modality tokens pre-embedded).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Note: 40 query heads do not divide the 16-way model axis.  The naive
fallback (shard on head_dim) makes XLA all-reduce full O(S²) score tensors —
~6 TB/chip/step at train_4k.  Default is therefore ``attn_head_padding``:
query heads are zero-padded 40→48 group-preservingly (numerically exact,
+20% attention-q FLOPs) so attention shards on heads; measured 12.8× cut of
the collective term (EXPERIMENTS.md §Perf).  Pass --no-pad via a config
override to reproduce the naive baseline.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig

DENSE = LayerSpec(mixer="attn", mlp="dense")
MOE = LayerSpec(mixer="attn", mlp="moe")

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(DENSE, MOE),  # ×24 — interleaved dense / MoE
    moe=MoEConfig(
        n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1,
        capacity_factor=1.25,
    ),
    tie_embeddings=False,
    rope_theta=500000.0,
    attn_head_padding=True,
)
