"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32) d_ff=5632
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),  # ×24
    tie_embeddings=False,
    rope_theta=10000.0,
)
