"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; local+global alternating attention, logit softcapping.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig, LayerSpec

LOCAL = LayerSpec(mixer="attn", window=4096, mlp="dense")
GLOBAL = LayerSpec(mixer="attn", window=None, mlp="dense")

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=(LOCAL, GLOBAL),  # ×23
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10000.0,
)
