"""whisper-medium [audio] — 24L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=51865; enc-dec with conv frontend STUB (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, EncoderConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,  # padded to 51968 for TP divisibility
    pattern=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),  # ×24 decoder
    encoder=EncoderConfig(n_layers=24, n_frames=1500, d_model=1024, n_heads=16, d_ff=4096),
    act="gelu",
    tie_embeddings=True,
)
