"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    SHAPES,
    ArchConfig,
    LayerSpec,
    ShapeSpec,
    applicable_shapes,
    make_reduced,
)

_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-4b": "qwen3_4b",
    "granite-8b": "granite_8b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-medium": "whisper_medium",
    "xlstm-1.3b": "xlstm_1_3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}


def list_archs():
    return sorted(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
