"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; unverified]

Pattern: (rglru, rglru, local-attn) × 12 super-blocks + 2 trailing recurrent
layers = 38.  Sub-quadratic (recurrent state + windowed cache) → runs the
long_500k decode cell.
"""
from repro.configs.base import ArchConfig, LayerSpec

REC = LayerSpec(mixer="rglru", mlp="dense")
ATT = LayerSpec(mixer="attn", window=2048, mlp="dense")

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=(REC, REC, ATT),  # ×12
    remainder=(REC, REC),
    rnn_width=4096,
    conv_width=4,
    act="gelu",
    tie_embeddings=True,
    subquadratic=True,
)
