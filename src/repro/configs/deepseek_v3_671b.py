"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280; MLA, MoE 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]

58 (MLA + MoE) layers scanned + 3 dense layers (d_ff=18432) as the unrolled
remainder (the real model places the dense layers first; the scan-friendly
layout places them last — structurally/roofline equivalent, noted in
DESIGN.md).  MTP is a lightweight extra prediction head (norm+proj+shared
embedding) rather than the full extra block, flagged via ``mtp=True``.
"""
from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig

MOE_LAYER = LayerSpec(mixer="attn", mlp="moe")
DENSE_LAYER = LayerSpec(mixer="attn", mlp="dense")

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,  # dense-layer FFN width
    vocab_size=129280,
    pattern=(MOE_LAYER,),  # ×58
    remainder=(DENSE_LAYER, DENSE_LAYER, DENSE_LAYER),
    mla=MLAConfig(
        q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
        capacity_factor=1.25,
    ),
    mtp=True,
    tie_embeddings=False,
    rope_theta=10000.0,
)
