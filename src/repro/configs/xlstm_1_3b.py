"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks at 7:1 ratio.  [arXiv:2405.04517; unverified]

mLSTM blocks carry their own 2× up/down projection (d_ff=0 in the paper's
table means "no separate FFN"); the sLSTM block is followed by a GeGLU FFN of
4/3 ratio (2688 ≈ 4/3·2048, rounded to a TP-16-divisible size) per the xLSTM
block design.  Pure recurrent state → runs the long_500k decode cell.
"""
from repro.configs.base import ArchConfig, LayerSpec

M = LayerSpec(mixer="mlstm", mlp="none")
S = LayerSpec(mixer="slstm", mlp="dense")

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=2688,
    vocab_size=50304,
    pattern=(M, M, M, M, M, M, M, S),  # ×6 — 7 mLSTM : 1 sLSTM
    rnn_width=4096,
    conv_width=4,
    act="gelu",
    tie_embeddings=True,
    subquadratic=True,
)
