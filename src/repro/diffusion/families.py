"""Relay family registry: schedules + net configs + trained parameters.

Each family carries a (large, small) pair sharing a latent space — the
paper's relay setup — plus an optional *mid*-size stage (ladder + net)
enabling L→M→S cascade programs (``repro.core.program``)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.relay import FamilySpec
from repro.core.schedules import karras_sigmas, rf_times
from repro.models import diffusion_nets as dn

T_EDGE_XL, T_DEV_XL = 50, 25  # SDXL / Vega (Karras, different ladders)
T_MID_XL = 40  # mid stage ("SSD-1B"): its own Karras ladder → real Eq. 4 hops
T_F3 = 50  # SD3.5 L and M (identical linear schedule), mid stage likewise


def xl_spec() -> FamilySpec:
    return FamilySpec(
        name="XL", kind="ddim",
        sigmas_edge=karras_sigmas(T_EDGE_XL),
        sigmas_device=karras_sigmas(T_DEV_XL),
        sigmas_mid=karras_sigmas(T_MID_XL),
    )


def f3_spec() -> FamilySpec:
    return FamilySpec(
        name="F3", kind="rf",
        sigmas_edge=rf_times(T_F3),
        sigmas_device=rf_times(T_F3),
        sigmas_mid=rf_times(T_F3),
    )


NET_CONFIGS = {
    ("XL", "large"): dn.XL_LARGE,
    ("XL", "mid"): dn.XL_MID,
    ("XL", "small"): dn.XL_SMALL,
    ("F3", "large"): dn.F3_LARGE,
    ("F3", "mid"): dn.F3_MID,
    ("F3", "small"): dn.F3_SMALL,
}

SPECS = {"XL": xl_spec, "F3": f3_spec}


def rf_velocity_from_x0(x0_hat, x, t):
    """RF velocity from the x̂0-parameterized net: v = (x_t − x̂0)/t."""
    t = jnp.maximum(jnp.asarray(t, jnp.float32), 1e-3)
    while t.ndim < x.ndim:
        t = t[..., None]
    return (x - x0_hat) / t


def vp_eps_from_x0(x0_hat, x, sigma):
    """VP ε̂ from the x̂0-parameterized net: ε̂ = (x − √ᾱ·x̂0)/√(1−ᾱ).
    Both nets predict the clean latent (well-conditioned at every noise
    level); DDIM/RF updates are unchanged."""
    from repro.core.schedules import vp_alpha_bar

    ab = vp_alpha_bar(jnp.asarray(sigma, jnp.float32))
    while ab.ndim < x.ndim:
        ab = ab[..., None]
    return (x - jnp.sqrt(ab) * x0_hat) / jnp.sqrt(jnp.maximum(1.0 - ab, 1e-6))


@dataclass
class Family:
    spec: FamilySpec
    large_cfg: dn.DiffNetConfig
    small_cfg: dn.DiffNetConfig
    large_params: dict
    small_params: dict
    mid_cfg: Optional[dn.DiffNetConfig] = None
    mid_params: Optional[dict] = None

    def _apply(self, cfg, params, x, t, cond):
        out = dn.apply_net(params, cfg, x, t, cond)
        if self.spec.kind == "rf":
            return rf_velocity_from_x0(out, x, t)  # x̂0-parameterized net
        return vp_eps_from_x0(out, x, t)

    def large_fn(self, params, x, t, cond):
        return self._apply(self.large_cfg, params, x, t, cond)

    def small_fn(self, params, x, t, cond):
        return self._apply(self.small_cfg, params, x, t, cond)

    def mid_fn(self, params, x, t, cond):
        if self.mid_cfg is None:
            raise ValueError(
                f"family {self.spec.name} has no mid-size net (train with "
                f"with_mid=True to enable cascade programs)"
            )
        return self._apply(self.mid_cfg, params, x, t, cond)

    @property
    def has_mid(self) -> bool:
        return self.mid_params is not None


def role_fn(family, role: str):
    """Denoiser callable of a model role — works for :class:`Family` and
    for the duck-typed toy families the tests build."""
    return getattr(family, f"{role}_fn")


def role_params(family, role: str):
    return getattr(family, f"{role}_params")


def make_family(name: str, large_params, small_params,
                mid_params=None) -> Family:
    return Family(
        spec=SPECS[name](),
        large_cfg=NET_CONFIGS[(name, "large")],
        small_cfg=NET_CONFIGS[(name, "small")],
        large_params=large_params,
        small_params=small_params,
        mid_cfg=NET_CONFIGS[(name, "mid")],
        mid_params=mid_params,
    )
