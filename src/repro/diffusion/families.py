"""Relay family registry: schedules + net configs + trained parameters."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.relay import FamilySpec
from repro.core.schedules import karras_sigmas, rf_times
from repro.models import diffusion_nets as dn

T_EDGE_XL, T_DEV_XL = 50, 25  # SDXL / Vega (Karras, different ladders)
T_F3 = 50  # SD3.5 L and M (identical linear schedule)


def xl_spec() -> FamilySpec:
    return FamilySpec(
        name="XL", kind="ddim",
        sigmas_edge=karras_sigmas(T_EDGE_XL),
        sigmas_device=karras_sigmas(T_DEV_XL),
    )


def f3_spec() -> FamilySpec:
    return FamilySpec(
        name="F3", kind="rf",
        sigmas_edge=rf_times(T_F3),
        sigmas_device=rf_times(T_F3),
    )


NET_CONFIGS = {
    ("XL", "large"): dn.XL_LARGE,
    ("XL", "small"): dn.XL_SMALL,
    ("F3", "large"): dn.F3_LARGE,
    ("F3", "small"): dn.F3_SMALL,
}

SPECS = {"XL": xl_spec, "F3": f3_spec}


def rf_velocity_from_x0(x0_hat, x, t):
    """RF velocity from the x̂0-parameterized net: v = (x_t − x̂0)/t."""
    t = jnp.maximum(jnp.asarray(t, jnp.float32), 1e-3)
    while t.ndim < x.ndim:
        t = t[..., None]
    return (x - x0_hat) / t


def vp_eps_from_x0(x0_hat, x, sigma):
    """VP ε̂ from the x̂0-parameterized net: ε̂ = (x − √ᾱ·x̂0)/√(1−ᾱ).
    Both nets predict the clean latent (well-conditioned at every noise
    level); DDIM/RF updates are unchanged."""
    from repro.core.schedules import vp_alpha_bar

    ab = vp_alpha_bar(jnp.asarray(sigma, jnp.float32))
    while ab.ndim < x.ndim:
        ab = ab[..., None]
    return (x - jnp.sqrt(ab) * x0_hat) / jnp.sqrt(jnp.maximum(1.0 - ab, 1e-6))


@dataclass
class Family:
    spec: FamilySpec
    large_cfg: dn.DiffNetConfig
    small_cfg: dn.DiffNetConfig
    large_params: dict
    small_params: dict

    def large_fn(self, params, x, t, cond):
        out = dn.apply_net(params, self.large_cfg, x, t, cond)
        if self.spec.kind == "rf":
            return rf_velocity_from_x0(out, x, t)  # x̂0-parameterized net
        return vp_eps_from_x0(out, x, t)

    def small_fn(self, params, x, t, cond):
        out = dn.apply_net(params, self.small_cfg, x, t, cond)
        if self.spec.kind == "rf":
            return rf_velocity_from_x0(out, x, t)
        return vp_eps_from_x0(out, x, t)


def make_family(name: str, large_params, small_params) -> Family:
    return Family(
        spec=SPECS[name](),
        large_cfg=NET_CONFIGS[(name, "large")],
        small_cfg=NET_CONFIGS[(name, "small")],
        large_params=large_params,
        small_params=small_params,
    )
