"""Training for the relay-family denoisers on the synthetic latent task.

Large models train on data (ε-prediction for XL/DDIM, velocity for F3/RF);
small models are *distilled* from their family's large model (mirroring
Vega←SDXL and the shared-data SD3.5 pair) — this is what makes the two
scales' denoising trajectories line up, the property relay inference needs.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.samplers import rf_noise, vp_noise
from repro.core.schedules import vp_alpha_bar
from repro.diffusion import synth
from repro.models import diffusion_nets as dn

SIGMA_MIN, SIGMA_MAX = 0.03, 10.0


def _sample_sigma(key, b, low_bias: bool = False):
    """Log-uniform σ in [σ_min, σ_max].  With ``low_bias`` (distillation),
    70% of samples come from the low-noise region the device model actually
    serves after a relay handoff (σ ≤ 1)."""
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, (b,))
    hi = jnp.where(
        jax.random.uniform(k2, (b,)) < (0.7 if low_bias else 0.0), 1.0, SIGMA_MAX
    )
    return jnp.exp(jnp.log(SIGMA_MIN) + u * (jnp.log(hi) - jnp.log(SIGMA_MIN)))


def _loss_xl(params, cfg, key, x0, cond):
    """x̂0-parameterized VP diffusion (ε̂ derived at sampling time — see
    families.vp_eps_from_x0; ε-prediction is ill-conditioned for x̂0
    recovery at high σ and underfits at this scale)."""
    b = x0.shape[0]
    k1, k2 = jax.random.split(key)
    sig = _sample_sigma(k1, b)
    ab = vp_alpha_bar(sig)[:, None, None, None]
    noise = jax.random.normal(k2, x0.shape)
    xt = jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * noise
    pred = dn.apply_net(params, cfg, xt, sig, cond)
    return jnp.mean(jnp.square(pred - x0))


def _loss_f3(params, cfg, key, x0, cond):
    """x̂0-parameterized rectified flow: the net predicts the clean latent
    (well-conditioned at every t; raw v-prediction needs 1/t input gain as
    t→0 and underfits badly at this scale).  The sampler derives
    v = (x_t − x̂0)/t — the same ODE."""
    b = x0.shape[0]
    k1, k2 = jax.random.split(key)
    t = jax.random.uniform(k1, (b,))
    noise = jax.random.normal(k2, x0.shape)
    xt = (1 - t)[:, None, None, None] * x0 + t[:, None, None, None] * noise
    pred = dn.apply_net(params, cfg, xt, t, cond)
    return jnp.mean(jnp.square(pred - x0))


def _loss_distill(params, cfg, teacher_params, teacher_cfg, family, key, x0, cond):
    """Student matches the teacher's prediction at sampled noise levels,
    mixed with a small data-loss term."""
    b = x0.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    if family == "XL":
        sig = _sample_sigma(k1, b, low_bias=True)
        ab = vp_alpha_bar(sig)[:, None, None, None]
        noise = jax.random.normal(k2, x0.shape)
        xt = jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * noise
        tvar = sig
        data_target = x0  # x̂0 parameterization (see _loss_xl)
    else:
        # bias toward the post-handoff region (t ≤ 0.6) the student serves
        t_lo = jax.random.uniform(k1, (b,)) * 0.6
        t_full = jax.random.uniform(k1, (b,))
        t = jnp.where(jax.random.uniform(k3, (b,)) < 0.7, t_lo, t_full)
        noise = jax.random.normal(k2, x0.shape)
        xt = (1 - t)[:, None, None, None] * x0 + t[:, None, None, None] * noise
        tvar = t
        data_target = x0  # x̂0 parameterization (see _loss_f3)
    teach = jax.lax.stop_gradient(
        dn.apply_net(teacher_params, teacher_cfg, xt, tvar, cond)
    )
    pred = dn.apply_net(params, cfg, xt, tvar, cond)
    return 0.8 * jnp.mean(jnp.square(pred - teach)) + 0.2 * jnp.mean(
        jnp.square(pred - data_target)
    )


def _adam_step(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    params = jax.tree.map(
        lambda p, mi, vi: p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps),
        params, m, v,
    )
    return params, m, v


def train_model(
    key,
    family: str,
    size: str,
    *,
    steps: int = 400,
    batch: int = 128,
    teacher=None,  # (params, cfg) → distillation mode
    seed0: int = 0,
    verbose: bool = False,
):
    cfg = __import__("repro.diffusion.families", fromlist=["NET_CONFIGS"]).NET_CONFIGS[
        (family, size)
    ]
    params = dn.init_net(key, cfg)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    if teacher is not None:
        t_params, t_cfg = teacher
        loss_fn = partial(_loss_distill, teacher_params=t_params, teacher_cfg=t_cfg,
                          family=family)
        loss_fn = lambda p, k, x, c: _loss_distill(p, cfg, t_params, t_cfg, family, k, x, c)
    elif family == "XL":
        loss_fn = lambda p, k, x, c: _loss_xl(p, cfg, k, x, c)
    else:
        loss_fn = lambda p, k, x, c: _loss_f3(p, cfg, k, x, c)

    base_lr = 3e-3 if cfg.kind == "mmdit" else 1e-3  # conv net needs lower

    @jax.jit
    def step_fn(params, m, v, key, x0, cond, i):
        loss, grads = jax.value_and_grad(loss_fn)(params, key, x0, cond)
        lr = base_lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * i / steps)))
        params, m, v = _adam_step(params, grads, m, v, i, lr)
        return params, m, v, loss

    t0 = time.time()
    losses = []
    for i in range(1, steps + 1):
        seeds = np.arange(seed0 + i * batch, seed0 + (i + 1) * batch)
        _, x0, cond = synth.batch(seeds, family)
        key, sub = jax.random.split(key)
        params, m, v, loss = step_fn(
            params, m, v, sub, jnp.asarray(x0), jnp.asarray(cond), jnp.float32(i)
        )
        losses.append(float(loss))
        if verbose and i % 100 == 0:
            print(f"  [{family}/{size}] step {i}: loss {loss:.4f} ({time.time()-t0:.0f}s)")
    return params, losses


def train_family_pair(key, family: str, *, steps_large=400, steps_small=400,
                      batch=64, verbose=False):
    k1, k2 = jax.random.split(key)
    large, ll = train_model(k1, family, "large", steps=steps_large, batch=batch,
                            verbose=verbose)
    from repro.diffusion.families import NET_CONFIGS

    small, ls = train_model(
        k2, family, "small", steps=steps_small, batch=batch,
        teacher=(large, NET_CONFIGS[(family, "large")]), verbose=verbose,
    )
    return large, small, {"loss_large": ll, "loss_small": ls}


def finetune_on_trajectories(
    key,
    family: str,
    large_params,
    small_params,
    *,
    steps: int = 400,
    n_traj: int = 192,
    batch: int = 128,
    verbose: bool = False,
):
    """Trajectory-matched distillation (beyond-paper alignment): fine-tune
    the student on states sampled from the *teacher's own sampling
    trajectories* — exactly the distribution the device model sees after a
    relay handoff — matching the teacher's prediction at each state.
    Tightens the Fig. 2 ρ_t deviation beyond plain forward-noising distill.
    """
    from repro.core import samplers
    from repro.diffusion.families import NET_CONFIGS, SPECS

    spec = SPECS[family]()
    lcfg = NET_CONFIGS[(family, "large")]
    scfg = NET_CONFIGS[(family, "small")]
    from repro.diffusion.families import rf_velocity_from_x0, vp_eps_from_x0

    if spec.kind == "rf":
        large_fn = lambda p, x, t, c: rf_velocity_from_x0(
            dn.apply_net(p, lcfg, x, t, c), x, t
        )
    else:
        large_fn = lambda p, x, t, c: vp_eps_from_x0(
            dn.apply_net(p, lcfg, x, t, c), x, t
        )

    # 1) build a pool of (x_t, t, cond) states from teacher trajectories
    k1, k2 = jax.random.split(key)
    seeds = np.arange(500_000, 500_000 + n_traj)
    _, _, cond = synth.batch(seeds, family)
    cond = jnp.asarray(cond)
    xT = jax.random.normal(k1, (n_traj,) + spec.latent_shape)
    sampler = samplers.rf_euler_sample if spec.kind == "rf" else samplers.ddim_sample
    _, traj = sampler(large_fn, large_params, xT, spec.sigmas_edge, cond)
    # traj: (T, n_traj, ...) states AFTER each step i → noise level sigmas[i+1]
    sig_pool = np.asarray(spec.sigmas_edge)[1:-1]  # drop final σ=0 state
    states = np.asarray(traj[:-1])  # (T-1, n_traj, ...)
    n_lvls = states.shape[0]

    # 2) fine-tune the student to match the teacher on pool states
    params = small_params
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, x, t, c):
        teach = jax.lax.stop_gradient(dn.apply_net(large_params, lcfg, x, t, c))
        return jnp.mean(jnp.square(dn.apply_net(p, scfg, x, t, c) - teach))

    @jax.jit
    def step_fn(params, m, v, x, t, c, i):
        loss, g = jax.value_and_grad(loss_fn)(params, x, t, c)
        lr = 5e-4 * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * i / steps)))
        params, m, v = _adam_step(params, g, m, v, i, lr)
        return params, m, v, loss

    rng = np.random.default_rng(0)
    for i in range(1, steps + 1):
        li = rng.integers(0, n_lvls, size=batch)
        ti = rng.integers(0, n_traj, size=batch)
        x = jnp.asarray(states[li, ti])
        t = jnp.asarray(sig_pool[li])
        c = cond[ti]
        params, m, v, loss = step_fn(params, m, v, x, t, c, jnp.float32(i))
        if verbose and i % 100 == 0:
            print(f"  [traj-distill {family}] step {i}: loss {float(loss):.5f}")
    return params


def get_or_train_families(
    ckpt_dir="results/ckpts", *, steps=400, batch=64, verbose=False,
    families=("XL", "F3"), with_mid=False,
):
    """Train (or load cached) relay families — shared by benchmarks/examples.

    ``with_mid=True`` additionally loads/trains each family's mid-size
    cascade stage (distilled from the large model, like the small one) —
    cached in its own ``diffusion_<fam>_mid.ckpt`` so existing pair
    checkpoints stay valid."""
    from pathlib import Path

    from repro.diffusion.families import NET_CONFIGS, make_family
    from repro.training import checkpoint as ckpt

    out = {}
    for i, fam in enumerate(families):
        path = Path(ckpt_dir) / f"diffusion_{fam}.ckpt"
        if path.exists():
            key = jax.random.PRNGKey(100 + i)
            large0 = dn.init_net(key, NET_CONFIGS[(fam, "large")])
            small0 = dn.init_net(key, NET_CONFIGS[(fam, "small")])
            tree, _ = ckpt.restore(path, {"large": large0, "small": small0})
            large, small = tree["large"], tree["small"]
        else:
            if verbose:
                print(f"training family {fam} ({steps} steps each)...")
            large, small, _ = train_family_pair(
                jax.random.PRNGKey(100 + i), fam,
                steps_large=steps, steps_small=steps, batch=batch,
                verbose=verbose,
            )
            # final alignment stage: trajectory-matched distillation
            # (tightens the Fig. 2 ρ_t deviation — see EXPERIMENTS.md)
            if steps >= 300:
                small = finetune_on_trajectories(
                    jax.random.PRNGKey(200 + i), fam, large, small,
                    steps=min(350, steps), verbose=verbose,
                )
            ckpt.save(path, {"large": large, "small": small})
        mid = None
        if with_mid:
            mid_path = Path(ckpt_dir) / f"diffusion_{fam}_mid.ckpt"
            if mid_path.exists():
                mid0 = dn.init_net(jax.random.PRNGKey(300 + i),
                                   NET_CONFIGS[(fam, "mid")])
                tree, _ = ckpt.restore(mid_path, {"mid": mid0})
                mid = tree["mid"]
            else:
                if verbose:
                    print(f"distilling mid-size {fam} stage ({steps} steps)...")
                mid, _ = train_model(
                    jax.random.PRNGKey(300 + i), fam, "mid", steps=steps,
                    batch=batch, teacher=(large, NET_CONFIGS[(fam, "large")]),
                    verbose=verbose,
                )
                ckpt.save(mid_path, {"mid": mid})
        out[fam] = make_family(fam, large, small, mid_params=mid)
    return out
