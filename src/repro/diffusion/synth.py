"""Synthetic text-to-image latent task.

Prompts are structured feature vectors; a fixed procedural renderer G(z)
produces the target 8×8×4 latent.  The text-rendering capability gap between
the two families is *mechanistic*: family F3's conditioning embedding carries
the text-pattern features (phase/frequency); family XL's does not — exactly
mirroring SDXL's inability to render legible text vs SD3.5 (paper Finding 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

HW = 8
CH = 4
CONTENT_DIM = 8
COND_DIM = 16

_rng = np.random.default_rng(1234)
_PROJ = _rng.normal(size=(CONTENT_DIM, 3 * 4)).astype(np.float32)  # blob params


@dataclass
class Prompt:
    seed: int
    content: np.ndarray  # (8,) scene features
    complexity: float  # ∈ [0,1] — number of clauses / objects
    wants_text: bool
    text_phase: np.ndarray  # (2,) phase/frequency of the glyph pattern


def sample_prompt(seed: int, *, p_text: float = 0.35) -> Prompt:
    rng = np.random.default_rng(seed)
    return Prompt(
        seed=seed,
        content=rng.normal(size=CONTENT_DIM).astype(np.float32),
        complexity=float(rng.uniform()),
        wants_text=bool(rng.uniform() < p_text),
        text_phase=rng.uniform(0, 2 * np.pi, size=2).astype(np.float32),
    )


STRIPE_FREQ = 3.0  # fixed glyph-band frequency; phase carries the content


def blob_params(prompt: Prompt) -> np.ndarray:
    """(12,) renderer parameters: 4 × (cx, cy, amp), squashed to (−1, 1)."""
    return np.tanh(prompt.content @ _PROJ).astype(np.float32)


def render(prompt: Prompt) -> np.ndarray:
    """G(z): deterministic target latent (8,8,4)."""
    yy, xx = np.mgrid[0:HW, 0:HW].astype(np.float32) / (HW - 1)
    lat = np.zeros((HW, HW, CH), np.float32)
    bp = blob_params(prompt)
    n_blobs = 1 + int(prompt.complexity * 3)
    for i in range(n_blobs):
        cx, cy, amp = bp[3 * i : 3 * i + 3]
        cx, cy = (cx + 1) / 2, (cy + 1) / 2
        g = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.08))
        lat[:, :, i % 3] += amp * g
    if prompt.wants_text:
        ph = prompt.text_phase[0]
        stripes = np.sin(2 * np.pi * STRIPE_FREQ * xx + ph)
        lat[:, :, 3] = 0.8 * stripes  # high-frequency "glyph" band
    return lat


def embed(prompt: Prompt, family: str) -> np.ndarray:
    """Conditioning vector per family — informative about composition, like a
    CLIP text embedding (it carries the renderer parameters directly; the
    glyph phase is sin/cos-encoded so the map to the stripe pattern is
    bilinear and learnable).  XL never sees the text features (Finding 2)."""
    e = np.zeros(COND_DIM, np.float32)
    e[:12] = blob_params(prompt)
    e[12] = prompt.complexity
    if family == "F3":
        ph = prompt.text_phase[0]
        flag = 1.0 if prompt.wants_text else 0.0
        e[13] = flag
        e[14] = flag * np.sin(ph)
        e[15] = flag * np.cos(ph)
    return e


def batch(seeds, family: str):
    ps = [sample_prompt(int(s)) for s in seeds]
    x0 = np.stack([render(p) for p in ps])
    cond = np.stack([embed(p, family) for p in ps])
    return ps, x0, cond
