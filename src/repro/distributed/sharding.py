"""Logical sharding rules: param/cache/activation PartitionSpecs.

Rules are keyed by the parameter's leaf name and expressed as an *ordered
candidate list*; the first candidate whose every sharded dimension divides
evenly is used, otherwise the leaf is replicated.  This gives per-arch
adaptivity for free — e.g. llama4's 40 query heads don't divide the 16-way
model axis, so its attention weights fall through to head_dim sharding;
recurrentgemma's single KV head falls through the same way.

A leading stacked ``n_repeats`` axis (scan-over-layers) is detected by rank
mismatch and left unsharded.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# "B" placeholder is replaced by the mesh's batch axes ("pod","data") / ("data",)
_B = "B"

# name → ordered candidates; each candidate is a tuple over dims
PARAM_RULES = {
    "embed": [("model", None)],
    "lm_head": [(None, "model")],
    "wq": [(None, "model", None), (None, None, "model")],
    "wk": [(None, "model", None), (None, None, "model")],
    "wv": [(None, "model", None), (None, None, "model")],
    "wo": [("model", None, None), (None, "model", None)],
    "w_gate": [(None, "model")],
    "w_up": [(None, "model")],
    "w_down": [("model", None)],
    "router": [(None, None)],
    "we_gate": [("model", None, None)],
    "we_up": [("model", None, None)],
    "we_down": [("model", None, None)],
    # MLA
    "w_dq": [(None, "model")],
    "w_uq": [(None, "model", None), (None, None, "model")],
    "w_dkv": [(None, None)],  # small; avoids resharding at the latent split
    "w_uk": [(None, "model", None)],
    "w_uv": [(None, "model", None)],
    # recurrent
    "w_x": [(None, "model")],
    "w_g": [(None, "model")],
    "conv_w": [(None, "model")],
    "conv_b": [("model",)],
    "w_a": [(None, "model")],
    "b_a": [("model",)],
    "w_i": [(None, "model")],
    "b_i": [("model",)],
    "lam": [("model",)],
    "w_out": [("model", None)],
    "w_if": [(None, None)],
    "b_if": [(None,)],
    # mLSTM block-diagonal per-head projections: shard the output dim
    "wq_h": [(None, None, "model")],
    "wk_h": [(None, None, "model")],
    "wv_h": [(None, None, "model")],
    "gn_scale": [("model",)],
    # sLSTM stays local to each shard (sequential scan) → replicated
    "w_gates": [(None, None)],
    "r_gates": [(None, None, None, None)],
    "b_gates": [(None,)],
    "ctx_proj": [(None, None)],
    "mtp_proj": [(None, "model")],
}

CACHE_RULES = {
    "k": [(_B, None, "model", None), (_B, "model", None, None), (None, "model", None, None)],
    "v": [(_B, None, "model", None), (_B, "model", None, None), (None, "model", None, None)],
    "c_kv": [(_B, None, "model"), (_B, "model", None), (None, "model", None)],
    "k_rope": [(_B, None, None)],
    "h": [(_B, "model"), (_B, None, "model"), (None, "model")],
    "conv": [(_B, None, "model")],
    "C": [(_B, None, "model", None), (None, None, "model", None)],
    "n": [(_B, None, "model"), (None, None, "model")],
    "m": [(_B, None), (None, None)],
    "c": [(_B, None, "model"), (None, None, "model")],
}


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def _fits(mesh: Mesh, cand: Sequence, shape: Tuple[int, ...]) -> bool:
    if len(cand) != len(shape):
        return False
    return all(d % _axis_size(mesh, ax) == 0 for d, ax in zip(shape, cand))


def _resolve(mesh: Mesh, cands, shape, name: str) -> P:
    ba = batch_axes(mesh)
    for cand in cands:
        cand = tuple(ba if ax == _B else ax for ax in cand)
        # stacked scan axis → prepend None
        if len(cand) == len(shape) - 1:
            cand = (None,) + cand
        if _fits(mesh, cand, shape):
            return P(*cand)
    return P()  # replicate


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_pspecs(params, mesh: Mesh):
    """Tree of PartitionSpec matching a parameter tree (or its eval_shape)."""

    def spec(path, leaf):
        name = _leaf_name(path)
        if name in PARAM_RULES:
            return _resolve(mesh, PARAM_RULES[name], leaf.shape, name)
        if "norm" in name or leaf.ndim <= 1:
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(spec, params)


def cache_pspecs(cache, mesh: Mesh, *, prefer_seq: bool = False):
    """``prefer_seq``: shard the cache's sequence axis on "model" instead of
    heads/latent — flash-decoding-style layout: each chip scans its local KV
    chunk and the softmax combine reduces tiny (B,H) vectors instead of
    all-reducing full score rows (deepseek decode §Perf iteration)."""
    seq_first = {
        "k": [(_B, "model", None, None), (_B, None, "model", None)],
        "v": [(_B, "model", None, None), (_B, None, "model", None)],
        "c_kv": [(_B, "model", None), (_B, None, "model")],
        "k_rope": [(_B, "model", None), (_B, None, None)],
    }

    def spec(path, leaf):
        name = _leaf_name(path)
        if prefer_seq and name in seq_first:
            return _resolve(mesh, seq_first[name], leaf.shape, name)
        if name in CACHE_RULES:
            return _resolve(mesh, CACHE_RULES[name], leaf.shape, name)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def zero_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh, axis: str = "data") -> P:
    """ZeRO-1: additionally shard one unsharded dim of an optimizer-state
    leaf along the data axis (first dim that divides evenly)."""
    if axis not in mesh.shape:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (d, ax) in enumerate(zip(shape, parts)):
        if ax is None and d % mesh.shape[axis] == 0 and d >= mesh.shape[axis]:
            parts[i] = axis
            return P(*parts)
    return spec


def data_pspec(mesh: Mesh, ndim: int) -> P:
    """Batch-sharded activation spec: (B, ...) → P(batch_axes, None, ...)."""
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def shardings_for(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
