"""GPipe-style pipeline parallelism over a "stage" mesh axis via shard_map +
collective_permute.

Each stage owns a contiguous slice of layers (stacked on a leading axis).
Microbatches stream through: at step t, stage p runs microbatch (t−p) and
passes activations to stage p+1 with ppermute.  After P−1 warm-up steps the
pipeline is full; total steps = n_micro + P − 1 (bubble fraction
(P−1)/(n_micro+P−1), reported by ``bubble_fraction``).

This module is the PP building block demonstrated on an MLP stack and
covered by equivalence tests (tests/test_distribution.py); the main archs
ship DP/TP/EP shardings (see DESIGN.md §6).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import pvary, shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(
    layer_fn: Callable,  # (layer_params, x) → x, applied per layer
    stage_params,  # pytree; leaves (n_stages, layers_per_stage, ...)
    x,  # (n_micro, micro_batch, d) microbatched input
    mesh: Mesh,
    *,
    axis: str = "stage",
):
    """Returns f(x) with layers partitioned across the `axis` mesh dimension."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    steps = n_micro + n_stages - 1

    def stage_fn(params, xs):
        # params: (1, layers_per_stage, ...) local slice; xs: (n_micro, mb, d)
        sid = jax.lax.axis_index(axis)
        params = jax.tree.map(lambda p: p[0], params)

        def run_stage(h):
            def body(h, lp):
                return layer_fn(lp, h), None

            h, _ = jax.lax.scan(body, h, params)
            return h

        mb = xs.shape[1]
        d = xs.shape[2]
        # carries start as stage-varying so the scan carry types stay stable
        buf = pvary(jnp.zeros((mb, d), xs.dtype), (axis,))
        out = pvary(jnp.zeros_like(xs), (axis,))

        def step(carry, t):
            buf, out = carry
            # stage 0 ingests microbatch t (if in range); others use buf
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(sid == 0, 1, 0) * jnp.where(t < n_micro, 1, 0)
            h_in = jnp.where(inject, xs[mb_idx], buf)
            h_out = run_stage(h_in)
            # last stage emits microbatch (t − n_stages + 1)
            emit_idx = t - (n_stages - 1)
            do_emit = (sid == n_stages - 1) & (emit_idx >= 0)
            idx = jnp.clip(emit_idx, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(out, idx, 0, keepdims=False)
            new = jnp.where(do_emit, h_out, cur)
            out = jax.lax.dynamic_update_index_in_dim(out, new, idx, 0)
            # pass activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(h_out, axis, perm)
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(step, (buf, out), jnp.arange(steps))
        # non-final stages hold zeros; psum broadcasts the final stage's out
        return jax.lax.psum(out, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        stage_fn, mesh=mesh,
        in_specs=(spec_p, P()), out_specs=P(),
    )(stage_params, x)
