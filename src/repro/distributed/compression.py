"""Compressed cross-pod collectives (DiLoCo-style periodic sync with error
feedback in launch/train.py).

The quantizers themselves live in :mod:`repro.quantization` — one module owns
every int8 round-trip (relay handoff transport, optimizer state, and these
collectives) so the relay's Eq.1-style deviation model and the collective's
error feedback share one code path.  This module keeps the collective
(`compressed_psum`); the historical quantizer re-exports completed their
deprecation cycle (DeprecationWarning through the previous releases) and now
raise ImportError pointing at the new home.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.quantization import fused_error_feedback_step, get_quantizer

Array = jax.Array

# historical API, now in repro.quantization — the lazy warning re-export
# shipped for the deprecation window; the window is over, so resolving an
# old name is now a hard error that says exactly where to import from
_MOVED = (
    "quant_rowwise", "dequant_rowwise", "quant_error",
    "quant_log8", "dequant_log8", "LOG8_RANGE",
    "latent_roundtrip_int8", "latent_roundtrip",
)


def __getattr__(name: str):
    if name in _MOVED:
        raise ImportError(
            f"repro.distributed.compression.{name} was removed after its "
            f"deprecation cycle; import repro.quantization.{name} instead"
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def compressed_psum(tree, mesh, axis: str = "pod", error_state=None,
                    quantizer="rowwise"):
    """Mean-reduce a pytree across ``axis`` in int8 with error feedback.

    Each shard quantizes (value + carried error) with ``quantizer`` (any
    name registered in ``repro.quantization.QUANTIZERS``), the dequantized
    payloads are psum'd, and the residual is carried to the next sync — so
    the accumulated mean converges to exact even though each individual
    sync is lossy.  Returns (reduced_tree, new_error_state).

    The per-shard round-trip goes through the *fused* quantizer step
    (``repro.quantization.fused_error_feedback_step`` — the same path the
    fused relay boundaries compose): the reconstruction computed for the
    error carry is the one fed to the psum, so the payload dequantizes
    exactly once per shard instead of twice.  Bit-identical to the
    two-dequant form.
    """
    qz = get_quantizer(quantizer)
    n = mesh.shape[axis]
    if error_state is None:
        error_state = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)

    def one(x, err):
        def body(x_l, e_l):
            _, rec, new_err = fused_error_feedback_step(x_l, e_l, qz)
            tot = jax.lax.psum(rec, axis)
            return tot / n, new_err

        spec = P(*([None] * x.ndim))
        return shard_map(
            body, mesh=mesh,
            in_specs=(spec, spec), out_specs=(spec, spec),
        )(x, err)

    flat, treedef = jax.tree.flatten(tree)
    eflat = jax.tree.leaves(error_state)
    out, errs = zip(*[one(x, e) for x, e in zip(flat, eflat)])
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, errs)
