"""Low-precision compression utilities: row-wise int8 quantization used for
(a) quantized optimizer states (halves/quarters the m/v HBM footprint of the
671B MoE) and (b) compressed cross-pod gradient/delta synchronization with
error feedback (DiLoCo-style periodic sync in launch/train.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def quant_rowwise(x: Array) -> dict:
    """Symmetric int8 quantization with one fp32 scale per last-dim row."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequant_rowwise(qs: dict) -> Array:
    return qs["q"].astype(jnp.float32) * qs["s"]


def quant_error(x: Array) -> Array:
    """Residual left behind by quantization (for error feedback)."""
    return x.astype(jnp.float32) - dequant_rowwise(quant_rowwise(x))


def latent_roundtrip_int8(x: Array):
    """Channel-rows int8 round-trip of a (..., H, W, C) latent — the relay
    handoff's wire format: each quantization row is one sample's spatial
    slice of one channel, one fp32 scale each (C scales per latent,
    matching ``repro.serving.latency.latent_wire_bytes``).  Rows never
    cross leading (batch) dims, so a sample's reconstruction is independent
    of its batch companions.

    Returns (reconstructed latent in x's dtype, payload bytes on the wire).
    jit-safe: the payload is a static Python int."""
    xm = jnp.moveaxis(x, -1, -3)  # (..., C, H, W)
    rows = xm.reshape(xm.shape[:-2] + (-1,))  # (..., C, H·W)
    qs = quant_rowwise(rows)
    rec = jnp.moveaxis(
        dequant_rowwise(qs).reshape(xm.shape), -3, -1
    ).astype(x.dtype)
    payload = qs["q"].size * qs["q"].dtype.itemsize + qs["s"].size * 4
    return rec, payload


# ---------------------------------------------------------------------------
# log-domain (dynamic-exponent) int8 — for Adam moments, whose within-row
# dynamic range spans orders of magnitude (linear int8 zeroes small v and
# destabilizes m/√v; cf. 8-bit Adam's dynamic tree quantization).
# ---------------------------------------------------------------------------

LOG8_RANGE = 24.0  # exponent range: 2^-24 … 1 relative to the row max


def quant_log8(x: Array) -> dict:
    """Signed log-scale int8: |q| ∈ 1..127 encodes log2(|x|/rowmax)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax, 1.0)
    r = jnp.abs(xf) / scale
    e = jnp.log2(jnp.maximum(r, 2.0 ** (-LOG8_RANGE - 1)))
    mag = jnp.round(127.0 * (1.0 + e / LOG8_RANGE))
    mag = jnp.where(r < 2.0 ** (-LOG8_RANGE), 0.0, jnp.clip(mag, 1, 127))
    q = (jnp.sign(xf) * mag).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequant_log8(qs: dict) -> Array:
    q = qs["q"].astype(jnp.float32)
    mag = jnp.abs(q)
    val = jnp.exp2(LOG8_RANGE * (mag / 127.0 - 1.0)) * qs["s"]
    return jnp.where(mag == 0, 0.0, jnp.sign(q) * val)


def compressed_psum(tree, mesh, axis: str = "pod", error_state=None):
    """Mean-reduce a pytree across ``axis`` in int8 with error feedback.

    Each shard quantizes (value + carried error), the int8 payloads are
    psum'd (widened to int32 on the wire — 4× fewer bytes than fp32 either
    way since scales are per-row), and the residual is carried to the next
    sync.  Returns (reduced_tree, new_error_state).
    """
    n = mesh.shape[axis]
    if error_state is None:
        error_state = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)

    def one(x, err):
        def body(x_l, e_l):
            v = x_l.astype(jnp.float32) + e_l
            qs = quant_rowwise(v)
            new_err = v - dequant_rowwise(qs)
            tot = jax.lax.psum(qs["q"].astype(jnp.int32) * qs["s"], axis)
            return tot / n, new_err

        spec = P(*([None] * x.ndim))
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(spec, spec), out_specs=(spec, spec),
        )(x, err)

    flat, treedef = jax.tree.flatten(tree)
    eflat = jax.tree.leaves(error_state)
    out, errs = zip(*[one(x, e) for x, e in zip(flat, eflat)])
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, errs)
