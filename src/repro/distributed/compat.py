"""JAX API compatibility for the distributed layer.

The distributed modules target the modern ``jax.shard_map`` / ``jax.lax.pvary``
API; the pinned container toolchain still ships them under
``jax.experimental.shard_map`` (and has no ``pvary`` at all — replication
tracking is the older ``check_rep`` machinery).  Import ``shard_map`` and
``pvary`` from here so every call site works on both.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):  # jax >= 0.6
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs):
        # check_rep=False: the legacy replication checker predates several
        # primitives these kernels use (sort-based dispatch, ppermute
        # schedules) and would reject otherwise-correct programs.
        return _shard_map_legacy(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:

    def pvary(x, axes):  # noqa: ARG001 - legacy jax has no varying types
        """No-op: pre-varying-types shard_map treats all values as varying."""
        return x
